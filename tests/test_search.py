"""Search correctness: recall floors vs brute force, snapshot
visibility, cache searchability during splits."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (UBISConfig, UBISDriver, brute_force, metrics,
                        search as search_mod)
from repro.core import version_manager as vm
from conftest import make_clustered


def _driver(n=4000, mode="ubis", dim=16):
    cfg = UBISConfig(dim=dim, max_postings=512, capacity=96, l_min=10,
                     l_max=80, cache_capacity=1024, max_ids=1 << 14,
                     use_pallas="off", mode=mode)
    data = make_clustered(n, d=dim, seed=3)
    drv = UBISDriver(cfg, data[:800], round_size=256, bg_ops_per_round=8)
    drv.insert(data, np.arange(n))
    drv.flush(max_ticks=50)
    return drv, cfg, data


def test_recall_floor():
    drv, cfg, data = _driver()
    q = make_clustered(64, d=16, seed=11)
    found = drv.search(q, 10).ids
    true, _ = brute_force(drv.state, cfg, jnp.asarray(q), 10)
    rec = metrics.recall_at_k(found, np.asarray(true))
    assert rec > 0.9, rec


def test_recall_after_churn():
    drv, cfg, data = _driver()
    rng = np.random.default_rng(0)
    # delete a third, insert fresh
    drv.delete(rng.choice(4000, size=1300, replace=False))
    fresh = make_clustered(1500, d=16, seed=77)
    drv.insert(fresh, np.arange(10000, 11500))
    drv.flush(max_ticks=50)
    q = make_clustered(64, d=16, seed=13)
    found = drv.search(q, 10).ids
    true, _ = brute_force(drv.state, cfg, jnp.asarray(q), 10)
    rec = metrics.recall_at_k(found, np.asarray(true))
    assert rec > 0.85, rec


def test_snapshot_visibility_gates_new_postings():
    """A posting whose weight exceeds the snapshot version is invisible:
    searches at an old version never see fresh postings."""
    drv, cfg, _ = _driver(n=1500)
    state = drv.state
    old_version = jnp.uint32(0)  # time-travel snapshot
    vis_now = vm.visible(state.rec_meta, state.allocated,
                         state.global_version)
    vis_then = vm.visible(state.rec_meta, state.allocated, old_version)
    # strictly fewer postings visible to the old snapshot (splits since)
    assert int(vis_then.sum()) < int(vis_now.sum())
    weights = np.asarray(vm.unpack_weight(state.rec_meta))
    then = np.asarray(vis_then)
    assert (weights[then] == 0).all()


def test_cached_vectors_searchable_mid_split():
    """Paper IV-B2: vectors parked in the cache during a split must be
    found by search before the split completes."""
    cfg = UBISConfig(dim=8, max_postings=128, capacity=64, l_min=4,
                     l_max=48, cache_capacity=256, max_ids=1 << 12,
                     use_pallas="off")
    data = make_clustered(800, d=8, k=2, seed=4)
    drv = UBISDriver(cfg, data[:100], round_size=128, bg_ops_per_round=2)
    drv.insert(data[:600], np.arange(600))
    # mark the fullest posting SPLITTING, then insert vectors aimed at it
    lengths = np.asarray(drv.state.lengths)
    pid = int(np.argmax(lengths))
    from repro.core.update import mark_status
    from repro.core.types import STATUS_SPLITTING
    drv.state = mark_status(drv.state, jnp.array([pid]), STATUS_SPLITTING)
    centroid = np.asarray(drv.state.centroids[pid])
    probe_vecs = (centroid[None] + 0.01 * np.random.default_rng(0).normal(
        size=(16, 8))).astype(np.float32)
    drv.insert(probe_vecs, np.arange(700, 716), tick_between=False)
    assert int(jnp.sum(drv.state.cache_valid)) > 0, "expected cache use"
    found = drv.search(probe_vecs, 3).ids
    hits = sum(1 for i, row in enumerate(found) if 700 + i in row.tolist())
    assert hits >= 14, f"cached vectors invisible to search ({hits}/16)"
