"""Serving-engine tests: deterministic batching, ticket/result
correspondence, overlap semantics, cadence, and tier_async equivalence.

The scheduling tests run against a stub index and a fake clock so the
fill-or-deadline decisions are a pure function of the arrival trace —
replaying a seeded trace twice must produce the identical
``batch_log``.  The semantic tests use real drivers.
"""
import dataclasses

import numpy as np
import pytest

from repro.api import SearchResult, TickReport, UpdateResult, make_index
from repro.core import UBISConfig, UBISDriver
from repro.serving import QueuedIndex, ServingConfig, ServingEngine
from conftest import make_clustered

DIM = 16


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class StubIndex:
    """Minimal StreamingIndex surface for pure-scheduling tests."""

    def __init__(self):
        self.calls = []

    def search(self, queries, k):
        q = np.asarray(queries)
        self.calls.append(("search", len(q), k))
        return SearchResult(ids=np.zeros((len(q), k), np.int32),
                            scores=np.zeros((len(q), k), np.float32))

    def insert(self, vecs, ids):
        self.calls.append(("insert", len(ids)))
        return UpdateResult(accepted=len(ids))

    def delete(self, ids):
        self.calls.append(("delete", len(ids)))
        return UpdateResult(deleted=len(ids))

    def tick(self):
        self.calls.append(("tick",))
        return TickReport()


def _cfg(**kw):
    base = dict(dim=DIM, max_postings=128, capacity=96, l_min=10,
                l_max=80, nprobe=128, max_ids=1 << 13, use_pallas="off")
    base.update(kw)
    return UBISConfig(**base)


def _replay(trace, scfg):
    """Feed a (time, kind) trace through an engine on a fake clock,
    pumping whenever the engine reports a due deadline; returns the
    batch log."""
    clock = FakeClock()
    idx = StubIndex()
    eng = ServingEngine(idx, scfg, clock=clock)
    rng = np.random.default_rng(7)
    for t, kind in trace:
        # advance time, firing any deadline that falls before t
        while True:
            nd = eng.next_deadline()
            if nd is None or nd > t:
                break
            clock.t = max(clock.t, nd)
            eng.pump()
        clock.t = t
        if kind == "search":
            eng.submit_search(rng.normal(size=DIM))
        else:
            eng.submit_insert(rng.normal(size=(4, DIM)), np.arange(4))
        eng.pump()                   # fill fires immediately, as due
    eng.drain()
    return eng.batch_log


def test_deadline_vs_fill_determinism():
    """Two replays of one seeded arrival trace produce the identical
    batch log — sizes AND reasons; both fire paths appear."""
    rng = np.random.default_rng(3)
    t = 0.0
    trace = []
    for _ in range(200):
        # bursts (sub-deadline gaps -> fill) and lulls (-> deadline)
        t += float(rng.choice([1e-5, 5e-3], p=[0.85, 0.15]))
        trace.append((t, "search" if rng.random() < 0.9 else "insert"))
    scfg = ServingConfig(search_batch=8, insert_batch=64,
                         search_deadline_s=2e-3, insert_deadline_s=4e-3,
                         tick_every=0)
    log1 = _replay(trace, scfg)
    log2 = _replay(trace, scfg)
    assert log1 == log2
    reasons = {r for _, _, r in log1}
    assert "fill" in reasons and "deadline" in reasons, reasons
    # a full lane fires at exactly search_batch, never more
    assert all(n <= 8 for lane, n, _ in log1 if lane == "search")
    assert any(n == 8 for lane, n, r in log1
               if lane == "search" and r == "fill")


def test_fill_fires_before_deadline():
    """A lane that reaches search_batch fires immediately ("fill") even
    though no request has aged past the deadline."""
    clock = FakeClock()
    eng = ServingEngine(StubIndex(),
                        ServingConfig(search_batch=4, tick_every=0,
                                      search_deadline_s=1.0),
                        clock=clock)
    for i in range(4):
        clock.t = i * 1e-6           # all well within the 1 s deadline
        eng.submit_search(np.zeros(DIM))
    assert eng.next_deadline() == clock.t     # due NOW
    assert eng.pump() == 4
    assert eng.batch_log == [("search", 4, "fill")]
    # below fill, nothing fires until the deadline passes
    eng.submit_search(np.zeros(DIM))
    assert eng.pump() == 0
    clock.t += 1.0
    assert eng.pump() == 1
    assert eng.batch_log[-1] == ("search", 1, "deadline")


def test_ticket_result_correspondence_interleaved():
    """Interleaved search + insert submissions: every ticket resolves
    to ITS OWN request's result — search rows match a direct batch
    search, insert tickets report their batch's counts."""
    data = make_clustered(900, d=DIM, k=8, seed=11)
    drv = UBISDriver(_cfg(), data[:300], round_size=256,
                     bg_ops_per_round=4)
    drv.insert(data[:600], np.arange(600))
    drv.flush(max_ticks=30)
    eng = ServingEngine(drv, ServingConfig(search_batch=8, tick_every=1))
    direct = drv.search(data[:24], 5)          # ground truth, pre-churn

    tickets = []
    fresh = iter(range(600, 900))
    for i in range(24):
        tickets.append(("search", i, eng.submit_search(data[i], k=5)))
        if i % 6 == 5:                         # weave the update lane in
            j = next(fresh)
            tickets.append(
                ("insert", j,
                 eng.submit_insert(data[j:j + 1], np.array([j]))))
    eng.drain()
    for kind, i, t in tickets:
        assert t.done()
        res = t.result()
        if kind == "search":
            assert isinstance(res, SearchResult)
            assert res.ids.shape == (1, 5)
            np.testing.assert_array_equal(res.ids[0], direct.ids[i])
            assert res.seconds >= 0.0 and t.latency_s >= 0.0
        else:
            # the four single-row inserts are consecutive in the update
            # lane, so one drain folds them into ONE driver call and
            # each ticket resolves to the group aggregate (per-op
            # exactness = drain per submit, i.e. QueuedIndex)
            assert isinstance(res, UpdateResult)
            assert res.accepted + res.cached == 4
    assert eng.counters["search_requests"] == 24
    assert eng.counters["update_jobs"] == 4


def test_overlap_answers_for_dispatch_time_state():
    """When a search batch and an insert flush share one pump, the
    search answers for the index AS OF DISPATCH — the in-flight insert
    is invisible to it, and visible to the next one."""
    data = make_clustered(600, d=DIM, k=6, seed=19)
    drv = UBISDriver(_cfg(), data[:200], round_size=256,
                     bg_ops_per_round=4)
    drv.insert(data[:400], np.arange(400))
    drv.flush(max_ticks=30)
    eng = ServingEngine(drv, ServingConfig(search_batch=4, tick_every=1))
    probe = data[500]
    t1 = eng.submit_search(probe, k=3)
    # exact duplicate of the probe under a fresh id, queued behind it
    eng.submit_insert(probe[None], np.array([8000]))
    eng.drain()                      # one pump: dispatch, insert, collect
    assert eng.counters["search_batches"] == 1
    assert 8000 not in set(t1.result().ids.ravel().tolist())
    t2 = eng.submit_search(probe, k=3)
    eng.drain()
    assert int(t2.result().ids[0, 0]) == 8000


def test_tick_cadence_knob():
    """tick_every=N runs one background tick per N update flushes;
    0 never ticks."""
    for every, flushes, want in ((1, 4, 4), (2, 4, 2), (0, 4, 0)):
        idx = StubIndex()
        eng = ServingEngine(idx, ServingConfig(tick_every=every))
        for i in range(flushes):
            eng.submit_insert(np.zeros((2, DIM), np.float32),
                              np.arange(2) + 10 * i)
            eng.drain()
        assert idx.calls.count(("tick",)) == want, (every, idx.calls)


def test_queued_index_matches_direct_driver():
    """QueuedIndex (submit -> drain per op) is semantically transparent:
    the same workload lands the same live contents and search answers
    as the bare driver."""
    data = make_clustered(1200, d=DIM, k=8, seed=23)
    live = {}
    res = {}
    for queued in (False, True):
        drv = UBISDriver(_cfg(), data[:300], round_size=256,
                         bg_ops_per_round=8)
        idx = QueuedIndex(drv) if queued else drv
        idx.insert(data[:800], np.arange(800))
        idx.delete(np.arange(100, 200))
        idx.tick()
        idx.insert(data[800:], np.arange(800, 1200))
        idx.flush(max_ticks=40)
        live[queued] = idx.live_count()
        res[queued] = idx.search(data[:16], 5)
    assert live[False] == live[True] == 1100
    np.testing.assert_array_equal(res[False].ids, res[True].ids)
    np.testing.assert_allclose(res[False].scores, res[True].scores,
                               rtol=1e-5)


TIER_KW = dict(use_pq=True, pq_m=4, pq_ksub=16, rerank_k=256,
               use_tier=True, tier_hot_max=8)


@pytest.mark.parametrize("engine", ("ubis", "ubis-sharded"))
def test_tier_async_matches_sync_liveness(engine):
    """Splitting the tier round into dispatch (tick start) / reconcile
    (tick end) never changes WHAT is live: the same tiered churn under
    tier_async holds the sync run's live multiset, keeps serving above
    the recall floor, and actually spills."""
    import jax
    kw = {}
    if engine == "ubis-sharded":
        kw["mesh"] = jax.make_mesh((1, 1), ("data", "model"))
    data = make_clustered(1500, d=DIM, k=8, seed=29)
    stats = {}
    for tier_async in (False, True):
        drv = make_index(engine, _cfg(capacity=96, **TIER_KW),
                         data[:300], round_size=256, bg_ops_per_round=8,
                         tier_async=tier_async, **kw)
        drv.insert(data[:900], np.arange(900))
        drv.tick()
        drv.force_spill(6)
        drv.insert(data[900:], np.arange(900, 1500))
        drv.delete(np.arange(0, 200))
        for _ in range(6):
            drv.tick()
        drv.flush(max_ticks=40)
        found = drv.search(data[300:332], 8).ids
        true = drv.exact(data[300:332], 8).ids
        hits = sum(len(set(f.tolist()) & set(t.tolist()))
                   for f, t in zip(np.asarray(found), np.asarray(true)))
        stats[tier_async] = dict(live=drv.live_count(),
                                 spilled=drv.stats["tier_spilled"],
                                 recall=hits / true.size)
    assert stats[False]["live"] == stats[True]["live"] == 1300
    assert stats[True]["spilled"] > 0
    assert stats[True]["recall"] >= 0.9, stats


def test_update_result_replace_keeps_counts():
    """Folded tickets get the group result with their own latency — the
    replace must never drop counts."""
    r = UpdateResult(accepted=3, cached=1, rejected=0)
    r2 = dataclasses.replace(r, seconds=0.5)
    assert (r2.accepted, r2.cached, r2.applied) == (3, 1, 4)
    assert r2.seconds == 0.5
