"""End-to-end behaviour tests for the full system (paper workload shape):
streaming ingest + concurrent search through the serving stack, and the
streaming-update recall story (UBIS >= SPFresh under churn)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import UBISConfig, UBISDriver, brute_force, metrics
from conftest import make_clustered


@pytest.mark.slow
def test_streaming_recall_ubis_beats_spfresh():
    """The paper's core claim, at reduced scale: under a streaming
    workload with background churn, UBIS indexes more fresh vectors and
    holds recall at least as high as SPFresh."""
    results = {}
    data = make_clustered(8000, d=16, k=24, seed=21)
    q = make_clustered(96, d=16, k=24, seed=22)
    for mode in ("ubis", "spfresh"):
        cfg = UBISConfig(dim=16, max_postings=512, capacity=96, l_min=10,
                         l_max=80, cache_capacity=2048, max_ids=1 << 14,
                         use_pallas="off", mode=mode)
        drv = UBISDriver(cfg, data[:800], round_size=256,
                         bg_ops_per_round=8)
        ingested = 0
        for off in range(0, 8000, 1000):
            r = drv.insert(data[off:off + 1000],
                           np.arange(off, off + 1000))
            ingested += r.accepted + r.cached
            drv.search(q[:32], 10)
            drv.tick()
        drv.flush(max_ticks=40)
        found = drv.search(q, 10).ids
        true, _ = brute_force(drv.state, cfg, jnp.asarray(q), 10)
        rec = metrics.recall_at_k(found, np.asarray(true))
        results[mode] = {"ingested": ingested, "recall": rec}
    assert results["ubis"]["ingested"] >= results["spfresh"]["ingested"]
    assert results["ubis"]["recall"] >= 0.9
    # freshness: UBIS should have indexed (nearly) everything
    assert results["ubis"]["ingested"] >= 8000 * 0.98, results


@pytest.mark.slow
def test_retrieval_server_end_to_end():
    """serve.py: embed -> streaming index -> query, with live recall."""
    from repro.launch.serve import RetrievalServer, ServeConfig
    cfg = ServeConfig(arch="tinyllama-1.1b", reduced=True, embed_dim=32)
    from repro.core import UBISConfig
    icfg = UBISConfig(dim=32, max_postings=256, capacity=96,
                      max_ids=1 << 14, use_pallas="off")
    rng = np.random.default_rng(0)
    seed_vecs = rng.normal(size=(256, 32)).astype(np.float32)
    srv = RetrievalServer(cfg, index_cfg=icfg, seed_vectors=seed_vecs)
    vocab = srv.embedder.model.cfg.vocab
    for _ in range(4):
        toks = rng.integers(0, vocab, (64, 16)).astype(np.int32)
        srv.ingest_tokens(toks)
    srv.index.flush(max_ticks=30)
    qt = rng.integers(0, vocab, (16, 16)).astype(np.int32)
    res = srv.query_tokens(qt, k=5)
    assert res.ids.shape == (16, 5)
    qv = srv.embedder.embed(qt)
    rec = srv.recall_check(qv, k=5)
    assert rec > 0.9, rec


def test_deletion_semantics():
    """Deleted ids never appear in search results; reinsertion works."""
    cfg = UBISConfig(dim=8, max_postings=256, capacity=64, l_min=4,
                     l_max=48, max_ids=1 << 12, use_pallas="off")
    data = make_clustered(1500, d=8, seed=5)
    drv = UBISDriver(cfg, data[:300], round_size=128, bg_ops_per_round=4)
    drv.insert(data, np.arange(1500))
    drv.flush(max_ticks=40)
    drv.delete(np.arange(0, 750))
    drv.flush(max_ticks=40)
    found = drv.search(data[:64], 10).ids
    bad = [int(f) for f in found.ravel() if 0 <= f < 750]
    assert not bad, f"deleted ids surfaced: {bad[:5]}"
    # reinsert deleted region with new ids
    drv.insert(data[:200], np.arange(2000, 2200))
    found = drv.search(data[:32], 5).ids
    assert any(f >= 2000 for f in found.ravel())
