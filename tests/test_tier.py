"""Cold-tier invariant suite (core/tier.py).

The residency contract, property-tested through the public driver
surface:

  * a spilled posting's PQ codes stay byte-identical to
    ``encode(codebooks[pinned slot], float tile)`` where the float tile
    now lives in the pinned host pool (and the device copy is zeroed);
  * a promote restores the float tile bit-identically;
  * split/merge/compact never run on a spilled posting — the detector
    masks them, and a structurally-due spilled posting is force-promoted
    by the tier planner before the op lands;
  * ``memory_tiers()`` device/host split sums to the untiered total;
  * the insert plane routes around spilled postings;
  * search (ADC-only + host rerank) and ``exact()`` (device scan + host
    pool merge) stay correct with most of the index spilled;
  * the codebook re-train promotes spilled postings pinned to the
    evicted slot before overwriting their codebook.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (UBISConfig, UBISDriver, balance, metrics,
                        state_memory_bytes, version_manager as vm)
from repro.quant import pq
from conftest import make_clustered

DIM = 16


def _cfg(**kw):
    base = dict(dim=DIM, max_postings=128, capacity=96, l_min=10,
                l_max=80, nprobe=128, max_ids=1 << 13,
                cache_capacity=2048, use_pallas="off",
                use_pq=True, pq_m=4, pq_ksub=16, rerank_k=256,
                use_tier=True, tier_hot_max=0)
    base.update(kw)
    return UBISConfig(**base)


def _driver(data, n_seed=300, **cfg_kw):
    drv = UBISDriver(_cfg(**cfg_kw), data[:n_seed], round_size=256,
                     bg_ops_per_round=8)
    drv.insert(data, np.arange(len(data)))
    drv.flush(max_ticks=60)
    return drv


def _audit_residency(drv):
    """The core invariant: every live posting's codes decode against the
    float plane that OWNS it (device tile if hot, pool tile if spilled),
    and spilled device tiles are zeroed."""
    state = drv.state
    cfg = drv.cfg
    status = np.asarray(vm.unpack_status(state.rec_meta))
    alive = np.asarray(state.allocated) & (status != 3)
    spilled = np.asarray(state.tier_spilled)
    sv = np.asarray(state.slot_valid)
    vecs = np.asarray(state.vectors)
    codes = np.asarray(state.codes)
    pslot = np.asarray(state.pq_posting_slot)
    cbs = np.asarray(state.pq_codebooks)
    n_sp = 0
    for p in np.flatnonzero(alive):
        if spilled[p]:
            assert p in drv.tier.pool, f"spilled {p} missing from pool"
            assert not vecs[p].any(), f"spilled {p} device tile not zeroed"
            tile = drv.tier.pool.get(int(p))
            n_sp += 1
        else:
            assert p not in drv.tier.pool, f"hot {p} still pooled"
            tile = vecs[p]
        want = np.asarray(pq.encode_tiles(
            jnp.asarray(cbs[pslot[p]]),
            jnp.asarray(tile)[None].astype(jnp.float32)))[0]
        got = codes[p]
        assert (got[:, sv[p]] == want[:, sv[p]]).all(), \
            f"code/float divergence at posting {p}"
    assert len(drv.tier.pool) == n_sp, "pool holds dead entries"
    return n_sp


def test_spill_promote_roundtrip_is_bit_identical():
    data = make_clustered(1500, d=DIM, k=8, seed=1)
    drv = _driver(data)
    state = drv.state
    status = np.asarray(vm.unpack_status(state.rec_meta))
    live = np.flatnonzero(np.asarray(state.allocated) & (status == 0)
                          & (np.asarray(state.lengths) > 0))
    assert len(live) >= 4
    before = {int(p): np.asarray(state.vectors[p]).tobytes()
              for p in live[:4]}

    moved = drv.force_spill(len(live))          # spill everything hot
    assert moved == len(live)
    sp = np.asarray(drv.state.tier_spilled)
    assert sp[live].all()
    _audit_residency(drv)

    promoted = drv.force_promote()
    assert promoted == moved
    assert not np.asarray(drv.state.tier_spilled).any()
    after = {p: np.asarray(drv.state.vectors[p]).tobytes() for p in before}
    assert after == before, "promote did not restore bit-identical tiles"
    assert len(drv.tier.pool) == 0


def test_residency_invariant_under_churn():
    """Mixed insert/delete/tick churn with forced spills interleaved:
    the code/float invariant holds for hot AND spilled postings, and
    the live multiset never drifts."""
    rng = np.random.default_rng(3)
    data = make_clustered(2400, d=DIM, k=10, seed=3)
    drv = _driver(data[:1200], tier_hot_max=12)
    live = set(range(1200))
    nxt = 1200
    for step in range(6):
        n = int(rng.integers(60, 180))
        drv.insert(data[nxt:nxt + n], np.arange(nxt, nxt + n))
        live |= set(range(nxt, min(nxt + n, len(data))))
        nxt = min(nxt + n, len(data))
        dels = rng.choice(sorted(live), size=min(50, len(live) // 4),
                          replace=False)
        drv.delete(dels)
        live -= set(int(x) for x in dels)
        if step % 2 == 0:
            drv.force_spill(int(rng.integers(2, 10)))
        drv.tick()
    drv.flush(max_ticks=60)
    assert drv.live_count() == len(live)
    n_sp = _audit_residency(drv)
    assert n_sp > 0, "watermark never spilled anything"
    # searches still meet the floor with the index mostly cold
    q = data[:24]
    rec = metrics.recall_at_k(drv.search(q, 8).ids, drv.exact(q, 8).ids)
    assert rec >= 0.9, rec


def test_detector_never_marks_spilled_postings():
    data = make_clustered(1500, d=DIM, k=8, seed=5)
    drv = _driver(data)
    drv.force_spill(10 ** 6)                      # spill everything
    split_due, merge_due, compact_due = balance.detect(drv.state, drv.cfg)
    sp = np.asarray(drv.state.tier_spilled)
    for mask in (split_due, merge_due, compact_due):
        assert not (np.asarray(mask) & sp).any(), \
            "detector marked a spilled posting"


def test_structural_op_on_spilled_posting_promotes_first():
    """Hollow a spilled posting below l_min: the tick must promote it
    (forced, structural-due) and only then merge it away — the posting
    is never split/merged while its float tile is host-resident."""
    data = make_clustered(1500, d=DIM, k=8, seed=7)
    drv = _driver(data)
    drv.force_spill(10 ** 6)
    state = drv.state
    status = np.asarray(vm.unpack_status(state.rec_meta))
    lengths = np.asarray(state.lengths)
    cand = np.flatnonzero(np.asarray(state.allocated) & (status == 0)
                          & np.asarray(state.tier_spilled)
                          & (lengths >= drv.cfg.l_min))
    assert cand.size, "no spilled posting to hollow out"
    p = int(cand[0])
    ids = np.asarray(state.ids[p])
    sv = np.asarray(state.slot_valid[p])
    doom = ids[sv][: int(lengths[p]) - drv.cfg.l_min + 1]
    drv.delete(doom)                              # now lengths[p] < l_min
    assert int(drv.state.lengths[p]) < drv.cfg.l_min
    assert bool(drv.state.tier_spilled[p])

    promoted_before_merge = False
    for _ in range(40):
        r = drv.tick()
        st = int(vm.unpack_status(drv.state.rec_meta[p]))
        sp_now = bool(drv.state.tier_spilled[p])
        if st in (1, 2):                          # marked for a structural op
            assert not sp_now, "posting marked while still spilled"
            promoted_before_merge = True
        if st == 3:                               # merged away (DELETED)
            assert promoted_before_merge or not sp_now
            break
    else:
        pytest.fail("hollowed spilled posting was never merged")
    _audit_residency(drv)


def test_forced_promotion_survives_the_same_ticks_spill_plan():
    """Regression: the spill plan runs after the promote batch in the
    same tick and used to read the STALE pre-promote heat — a
    structurally-due posting was promoted and immediately re-evicted,
    which with ``promote_heat <= cold_heat`` is a permanent
    promote/spill livelock (the merge never lands).  A promoted posting
    must end its tick float-resident, and the due op must resolve."""
    data = make_clustered(1500, d=DIM, k=8, seed=19)
    # degenerate knobs on purpose: a freshly-promoted posting's warm
    # heat still sits at/below the cold threshold
    drv = UBISDriver(_cfg(tier_hot_max=8, tier_promote_heat=2,
                          tier_cold_heat=2),
                     data[:300], round_size=256, bg_ops_per_round=8)
    drv.insert(data, np.arange(1500))
    drv.flush(max_ticks=60)
    state = drv.state
    status = np.asarray(vm.unpack_status(state.rec_meta))
    lengths = np.asarray(state.lengths)
    cand = np.flatnonzero(np.asarray(state.allocated) & (status == 0)
                          & np.asarray(state.tier_spilled)
                          & (lengths >= drv.cfg.l_min))
    assert cand.size, "watermark left nothing spilled"
    p = int(cand[0])
    ids = np.asarray(state.ids[p])
    sv = np.asarray(state.slot_valid[p])
    drv.delete(ids[sv][: int(lengths[p]) - drv.cfg.l_min + 1])
    r = drv.tick()                                # forced promotion tick
    assert r.promoted >= 1, r
    assert not bool(drv.state.tier_spilled[p]), \
        "promoted posting was re-spilled in the same tick"
    n = drv.flush(max_ticks=40)
    assert n < 40, "tier moves never quiesced (promote/spill livelock)"
    assert int(vm.unpack_status(drv.state.rec_meta[p])) == 3, \
        "the due merge never landed"
    _audit_residency(drv)


def test_memory_tiers_split_sums_to_untiered_total():
    data = make_clustered(1500, d=DIM, k=8, seed=9)
    drv = _driver(data)
    total = state_memory_bytes(drv.state)
    t0 = drv.memory_tiers()
    assert t0["device"] + t0["host"] == total == drv.memory_bytes()
    assert t0["host"] == 0

    n = drv.force_spill(7)
    tb = drv.cfg.capacity * DIM * 4               # f32 tile bytes
    t1 = drv.memory_tiers()
    assert t1["host"] == n * tb == drv.tier.pool.nbytes()
    assert t1["device"] == total - n * tb
    assert t1["device"] + t1["host"] == drv.memory_bytes()

    drv.force_promote()
    t2 = drv.memory_tiers()
    assert t2 == {"device": total, "host": 0}


def test_inserts_route_around_spilled_postings():
    data = make_clustered(1500, d=DIM, k=8, seed=11)
    drv = _driver(data)
    drv.force_spill(10 ** 6)
    sp = np.flatnonzero(np.asarray(drv.state.tier_spilled))
    len_before = np.asarray(drv.state.lengths)[sp]
    used_before = np.asarray(drv.state.used)[sp]
    fresh = make_clustered(200, d=DIM, k=8, seed=11)   # same clusters
    r = drv.insert(fresh, np.arange(4000, 4200))
    assert r.accepted + r.cached == 200
    still = np.asarray(drv.state.tier_spilled)[sp]     # none promoted yet
    assert (np.asarray(drv.state.used)[sp][still]
            == used_before[still]).all(), \
        "an append landed in a spilled posting's tile"
    assert (np.asarray(drv.state.lengths)[sp][still]
            >= len_before[still] - 0).all()
    drv.flush(max_ticks=60)
    assert drv.live_count() == 1500 + 200
    _audit_residency(drv)


def test_exact_oracle_matches_numpy_under_spill():
    data = make_clustered(1200, d=DIM, k=6, seed=13)
    drv = _driver(data)
    drv.force_spill(10 ** 6)
    q = make_clustered(16, d=DIM, k=6, seed=14)
    d2 = ((q[:, None, :] - data[None]) ** 2).sum(-1)
    true = np.argsort(d2, axis=1)[:, :10]
    got = drv.exact(q, 10)
    assert metrics.recall_at_k(np.asarray(got.ids), true) == 1.0
    # the two-stage search path (ADC over spilled + host rerank) holds
    rec = metrics.recall_at_k(drv.search(q, 10).ids, np.asarray(got.ids))
    assert rec >= 0.9, rec


def test_retrain_promotes_pinned_spilled_postings():
    """The codebook re-train overwrites the evicted slot: spilled
    postings pinned to it must be promoted first (else their codes
    become undecodable) — then the residency invariant still holds."""
    data = make_clustered(1500, d=DIM, k=8, seed=15)
    drv = UBISDriver(_cfg(), data[:300], round_size=256,
                     bg_ops_per_round=8, pq_retrain_every=1)
    drv.insert(data, np.arange(len(data)))
    drv.force_spill(10 ** 6)
    n_sp = len(drv.tier.pool)
    assert n_sp > 0
    for _ in range(3):                            # retrains every tick
        drv.tick()
    assert drv.stats["pq_retrains"] >= 3
    _audit_residency(drv)
    q = data[:16]
    rec = metrics.recall_at_k(drv.search(q, 8).ids, drv.exact(q, 8).ids)
    assert rec >= 0.9, rec


def test_watermark_spills_cold_not_hot():
    """With a hot query working set, the watermark evicts the unqueried
    (cold) postings and the queried ones stay float-resident."""
    rng = np.random.default_rng(17)
    cents = rng.normal(size=(10, DIM)) * 8
    a = rng.integers(0, 10, 2000)
    data = (cents[a] + rng.normal(size=(2000, DIM))).astype(np.float32)
    drv = UBISDriver(_cfg(tier_hot_max=8, nprobe=4), data[:300],
                     round_size=256, bg_ops_per_round=8)
    drv.insert(data, np.arange(2000))
    hot_q = (cents[0] + rng.normal(size=(32, DIM))).astype(np.float32)
    for _ in range(8):
        drv.search(hot_q, 8)                      # heat cluster 0 only
        drv.tick()
    assert drv.stats["tier_spilled"] > 0
    r = drv.tick()
    assert r.spilled >= 0 and r.promoted >= 0     # TickReport surface
    # the postings the hot queries probe remained float-resident
    found, _, probe = __import__("repro.core.search", fromlist=["search"]
                                 ).search(drv.state, drv.cfg,
                                          jnp.asarray(hot_q), 8, 4)
    probed = np.unique(np.asarray(probe))
    sp = np.asarray(drv.state.tier_spilled)
    assert not sp[probed].all(), "the hot working set was fully evicted"
    _audit_residency(drv)
