"""Posting Recorder (version manager) unit + property tests."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import version_manager as vm
from repro.core.types import (NO_SUCC, STATUS_DELETED, STATUS_MERGING,
                              STATUS_NORMAL, STATUS_SPLITTING)

settings.register_profile("fast", max_examples=25, deadline=None)
settings.load_profile("fast")


@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 2 ** 30 - 1)),
                min_size=1, max_size=40))
def test_pack_unpack_roundtrip(pairs):
    status = jnp.array([p[0] for p in pairs], jnp.uint32)
    weight = jnp.array([p[1] for p in pairs], jnp.uint32)
    meta = vm.pack_meta(status, weight)
    np.testing.assert_array_equal(vm.unpack_status(meta), status)
    np.testing.assert_array_equal(vm.unpack_weight(meta), weight)


@given(st.lists(st.tuples(st.integers(0, 0xFFFF), st.integers(0, 0xFFFF)),
                min_size=1, max_size=40))
def test_succ_roundtrip(pairs):
    s1 = jnp.array([p[0] for p in pairs], jnp.uint32)
    s2 = jnp.array([p[1] for p in pairs], jnp.uint32)
    packed = vm.pack_succ(s1, s2)
    u1, u2 = vm.unpack_succ(packed)
    np.testing.assert_array_equal(u1, s1)
    np.testing.assert_array_equal(u2, s2)
    g1, g2 = vm.succ_ids(packed)
    expect = np.asarray(s1).astype(np.int64)
    expect[expect == NO_SUCC] = -1
    np.testing.assert_array_equal(np.asarray(g1).astype(np.int64), expect)


@given(st.lists(st.integers(-1, 15), min_size=1, max_size=64))
def test_first_occurrence_mask(xs):
    m = np.asarray(vm.first_occurrence_mask(jnp.array(xs)))
    seen = set()
    for x, flag in zip(xs, m):
        assert flag == (x not in seen)
        seen.add(x)


def test_transition_one_winner_per_word():
    """CAS semantics: duplicate pids in one round -> first writer wins."""
    meta = vm.pack_meta(jnp.zeros(8, jnp.uint32), jnp.arange(8))
    pids = jnp.array([3, 3, 5, -1, 3], jnp.int32)
    out = vm.transition(meta, pids, STATUS_SPLITTING)
    st_ = np.asarray(vm.unpack_status(out))
    assert st_[3] == STATUS_SPLITTING and st_[5] == STATUS_SPLITTING
    assert (st_[[0, 1, 2, 4, 6, 7]] == STATUS_NORMAL).all()
    # weights preserved when not specified
    np.testing.assert_array_equal(vm.unpack_weight(out), jnp.arange(8))


def test_visibility_rule():
    meta = vm.pack_meta(
        jnp.array([STATUS_NORMAL, STATUS_DELETED, STATUS_NORMAL,
                   STATUS_MERGING], jnp.uint32),
        jnp.array([0, 0, 10, 2], jnp.uint32))
    alloc = jnp.array([True, True, True, False])
    vis = np.asarray(vm.visible(meta, alloc, jnp.uint32(5)))
    # [normal w0 -> vis; deleted -> no; normal w10 > snapshot 5 -> no;
    #  unallocated -> no]
    np.testing.assert_array_equal(vis, [True, False, False, False])


def test_chase_successors():
    """DELETED chains resolve to the nearer successor; dead ends flag."""
    M, d = 8, 4
    meta = vm.pack_meta(
        jnp.array([3, 0, 0, 3, 3, 0, 3, 3], jnp.uint32),  # 0,3,4 deleted
        jnp.zeros(8, jnp.uint32))
    succ = vm.pack_succ(
        jnp.array([1, NO_SUCC, NO_SUCC, 4, NO_SUCC, NO_SUCC, NO_SUCC,
                   NO_SUCC], jnp.uint32),
        jnp.array([2, NO_SUCC, NO_SUCC, NO_SUCC, NO_SUCC, NO_SUCC,
                   NO_SUCC, NO_SUCC], jnp.uint32))
    cents = jnp.zeros((M, d)).at[1].set(1.0).at[2].set(-1.0)
    alloc = jnp.ones(8, bool)
    pts = jnp.array([[0.9, 0.9, 0.9, 0.9], [-.9, -.9, -.9, -.9],
                     [0.0, 0, 0, 0], [0, 0, 0, 0]])
    pids = jnp.array([0, 0, 3, 6], jnp.int32)
    out, dead = vm.chase_successors(meta, succ, alloc, cents, pids, pts, 4)
    out = np.asarray(out)
    assert out[0] == 1          # nearer centroid picked
    assert out[1] == 2
    assert bool(dead[2])        # 3 -> 4 (deleted, no succ) dead end
    assert bool(dead[3])        # 6 deleted, no succ
    assert not bool(dead[0]) and not bool(dead[1])
